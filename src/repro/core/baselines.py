"""GPS baselines the paper compares against, on the same block substrate.

``global_minplus`` / ``global_push`` are synchronous global-frontier engines:
every round streams *every* active block of the whole graph — the behaviour of
Ligra/Gemini/GraphIt-style systems.  Two accounting modes mirror the paper's
threading schemes:

  t=10 (intra-query): queries run ONE AT A TIME, each round streams the blocks
       its frontier touches.  Traffic = sum over queries of their own streams.
  t=1  (inter-query): all queries run CONCURRENTLY; each round the union of
       frontiers is relaxed, but each query's accesses are uncoordinated, so
       modeled traffic counts blocks PER QUERY (no reuse across queries) —
       the cache-thrashing analogue of Table 1 / Figure 2.

Values produced are identical (synchronous Bellman-Ford / Jacobi push);
what differs is work/traffic accounting and wall time.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DeviceGraph
from repro.core.graph import BlockGraph
from repro.core.yielding import NO_YIELD
from repro.kernels.minplus import ops as minplus_ops

INF = jnp.inf


@dataclasses.dataclass
class BaselineResult:
    values: np.ndarray
    edges_processed: np.ndarray   # [Q]
    rounds: int
    modeled_bytes: float          # uncoordinated traffic model
    modeled_bytes_shared: float   # perfectly-shared traffic (lower bound)


def _block_state(dg: DeviceGraph, sources: np.ndarray) -> jax.Array:
    P, B = dg.num_parts, dg.block_size
    Q = len(sources)
    dist = jnp.full((P, Q, B), INF, dtype=jnp.float32)
    parts = np.asarray(sources) // B
    locs = np.asarray(sources) % B
    return dist.at[parts, np.arange(Q), locs].set(0.0)


def make_minplus_round(dg: DeviceGraph, blk_src: jax.Array,
                       blk_dst: jax.Array):
    """The jitted synchronous Bellman-Ford round: (dist, frontier) ->
    (dist', improved, eq).  Module-level so the fppcheck program
    inventory (analysis/programs.py) traces exactly the program
    ``global_minplus`` runs."""
    nblk = dg.blocks.shape[0]

    @jax.jit
    def round_fn(dist, frontier):
        # relax every block whose source partition has frontier rows
        srcs = jnp.where(frontier, dist, INF)            # [P, Q, B]

        def one_block(k, cand):
            s = srcs[blk_src[k]]
            out = minplus_ops.minplus(s, dg.blocks[k])
            return cand.at[blk_dst[k]].min(out)

        cand = jax.lax.fori_loop(0, nblk, one_block,
                                 jnp.full_like(dist, INF))
        improved = cand < dist
        dist = jnp.minimum(dist, cand)
        # per-query edges: frontier rows' degree — int32 on device, the
        # host accumulator widens to float64 across rounds
        eq = jnp.sum(jnp.where(frontier, dg.deg[:, None, :], 0),
                     axis=(0, 2), dtype=jnp.int32)
        return dist, improved, eq

    return round_fn


def global_minplus(bg: BlockGraph, sources: np.ndarray,
                   max_rounds: int | None = None,
                   init_plane: np.ndarray | None = None) -> BaselineResult:
    """Synchronous global Bellman-Ford over all blocks (Ligra-like).

    ``init_plane`` ([P, B], +inf empty) replaces the one-hot source state for
    the every-vertex-is-a-source kinds: cc seeds each vertex with its own
    label and the synchronous rounds become min-label propagation (sources
    then only set the lane count).
    """
    dg = DeviceGraph.build(bg, NO_YIELD, len(sources))
    P, B, Q = dg.num_parts, dg.block_size, len(sources)
    max_rounds = max_rounds or (bg.n + 1)
    blk_src = jnp.asarray(bg.blk_src.astype(np.int32))
    blk_dst = jnp.asarray(bg.blk_dst.astype(np.int32))
    round_fn = make_minplus_round(dg, blk_src, blk_dst)

    if init_plane is not None:
        dist = jnp.broadcast_to(
            jnp.asarray(init_plane, dtype=jnp.float32)[:, None, :],
            (P, Q, B))
    else:
        dist = _block_state(dg, sources)
    frontier = jnp.isfinite(dist)
    edges = np.zeros(Q, dtype=np.float64)
    bpd = float(B * B * 4)          # bytes per block stream
    traffic_unshared = traffic_shared = 0.0
    rounds = 0
    fr_np = np.asarray(frontier)
    while rounds < max_rounds and fr_np.any():
        # traffic model: blocks touched this round
        part_active = fr_np.any(axis=2)                  # [P, Q]
        out_deg_blocks = 1 + (bg.nbr_blk >= 0).sum(axis=1)  # incl. diagonal
        per_query_blocks = (part_active * out_deg_blocks[:, None]).sum(axis=0)
        traffic_unshared += float(per_query_blocks.sum()) * bpd
        traffic_shared += float(
            (part_active.any(axis=1) * out_deg_blocks).sum()) * bpd
        dist, improved, eq = round_fn(dist, frontier)
        edges += np.asarray(eq, dtype=np.float64)
        frontier = improved
        fr_np = np.asarray(frontier)
        rounds += 1
    vals = np.asarray(dist).transpose(1, 0, 2).reshape(Q, -1)[:, :bg.n]
    return BaselineResult(vals, edges, rounds, traffic_unshared,
                          traffic_shared)


def make_walk_round(dg: DeviceGraph, length: int, seed: int):
    """The jitted synchronous random-walk round: one tape entry for every
    live walker at once (Ligra-style bulk stepping — no partition residency).
    Module-level so the fppcheck inventory traces exactly the program
    ``global_random_walks`` runs.  Same per-(source, step) tape as the
    partition-resident engine loop (core/randomwalk.py), so trajectories
    are bitwise identical."""
    from repro.core.randomwalk import make_walk_stepper
    step = make_walk_stepper(dg, length, seed)

    @jax.jit
    def round_fn(pos, steps, part, src, thash, occ):
        return step(pos, steps, part, src, thash, occ, steps < length)

    return round_fn


def global_random_walks(bg: BlockGraph, sources: np.ndarray, length: int,
                        seed: int = 0):
    """Synchronous bulk random walks: every live walker steps once per round
    for ``length`` rounds — the inter-query baseline for the rw kind."""
    from repro.core.randomwalk import WalkResult, init_walk_state
    dg = DeviceGraph.build(bg, NO_YIELD, len(sources))
    round_fn = make_walk_round(dg, length, seed)
    pos, steps, part, src, thash, occ = init_walk_state(dg, sources)
    for _ in range(length):
        pos, steps, part, thash, occ = round_fn(pos, steps, part, src,
                                                thash, occ)
    return WalkResult(np.asarray(pos), np.asarray(steps), np.asarray(thash),
                      visits=length, occupancy=np.asarray(occ)[:, :bg.n])


def make_push_round(dg: DeviceGraph, blk_src: jax.Array,
                    blk_dst: jax.Array, *, alpha: float, eps: float):
    """The jitted synchronous Jacobi push round: (p, r) ->
    (p', r', active, eq).  Module-level for the same reason as
    :func:`make_minplus_round`."""
    nblk = dg.blocks.shape[0]
    degc = jnp.maximum(dg.deg, 1).astype(jnp.float32)    # [P, B]
    has_edges = dg.deg > 0

    @jax.jit
    def round_fn(p, r):
        active = (r >= eps * degc[:, None, :]) & has_edges[:, None, :]
        af = active.astype(r.dtype)
        p = p + alpha * r * af
        push = (1.0 - alpha) * r * af / degc[:, None, :]

        def one_block(k, acc):
            s = push[blk_src[k]]
            out = minplus_ops.masked_matmul(s, dg.blocks[k])
            return acc.at[blk_dst[k]].add(out)

        spread = jax.lax.fori_loop(0, nblk, one_block, jnp.zeros_like(r))
        r = r * (1.0 - af) + spread
        eq = jnp.sum(jnp.where(active, dg.deg[:, None, :], 0),
                     axis=(0, 2), dtype=jnp.int32)
        return p, r, active, eq

    return round_fn


def global_push(bg: BlockGraph, sources: np.ndarray, alpha: float = 0.15,
                eps: float = 1e-4, max_rounds: int = 10_000) -> BaselineResult:
    """Synchronous global Jacobi push PPR (GraphIt-like PageRankDelta)."""
    dg = DeviceGraph.build(bg, NO_YIELD, len(sources))
    P, B, Q = dg.num_parts, dg.block_size, len(sources)
    blk_src = jnp.asarray(bg.blk_src.astype(np.int32))
    blk_dst = jnp.asarray(bg.blk_dst.astype(np.int32))
    round_fn = make_push_round(dg, blk_src, blk_dst, alpha=alpha, eps=eps)

    r = _block_state(dg, sources)
    r = jnp.where(jnp.isfinite(r), 1.0, 0.0)
    p = jnp.zeros_like(r)
    edges = np.zeros(Q, dtype=np.float64)
    bpd = float(B * B * 4)
    traffic_unshared = traffic_shared = 0.0
    rounds = 0
    while rounds < max_rounds:
        pv, rv, active, eq = round_fn(p, r)
        act_np = np.asarray(active)
        if not act_np.any():
            break
        part_active = act_np.any(axis=2)
        out_deg_blocks = 1 + (bg.nbr_blk >= 0).sum(axis=1)
        per_query_blocks = (part_active * out_deg_blocks[:, None]).sum(axis=0)
        traffic_unshared += float(per_query_blocks.sum()) * bpd
        traffic_shared += float(
            (part_active.any(axis=1) * out_deg_blocks).sum()) * bpd
        p, r = pv, rv
        edges += np.asarray(eq, dtype=np.float64)
        rounds += 1
    vals = np.asarray(p).transpose(1, 0, 2).reshape(Q, -1)[:, :bg.n]
    return BaselineResult(vals, edges, rounds, traffic_unshared,
                          traffic_shared)
