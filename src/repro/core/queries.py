"""Query-type facade: the FPP query types ForkGraph supports (paper §3).

BFS / SSSP ride the minplus engine, PPR rides the push engine, CC rides the
minplus engine over a zero-weight variant with every-vertex label init,
weighted k-reach rides it over hop-shifted weights (lexicographic
(hops, dist) packing, see ``oracles.kreach_stride``), RW has its own
buffered walker loop, DFS is host-only (oracles.dfs_order; see DESIGN.md §2).
All functions take sources in the *reordered* vertex id space of ``bg`` (use
``perm[old_id]`` from partition()); the weight-variant kinds expect ``bg``
built from the matching :func:`reweight` of the CSR.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.engine import EngineResult, FPPEngine
from repro.core.graph import BlockGraph, CSRGraph
from repro.core.oracles import kreach_stride
from repro.core.partition import partition
from repro.core.randomwalk import WalkResult, run_random_walks
from repro.core.yielding import YieldConfig, default_delta

#: weight variant per kind; every other kind runs the natural weights
WEIGHT_VARIANTS = {"bfs": "unit", "cc": "zero", "kreach": "shift"}


def reweight(g: CSRGraph, variant: str,
             stride: Optional[float] = None) -> CSRGraph:
    """The kind's weight transform, applied at the CSR level so every
    backend partitions the *same* structure (identical perm across
    variants) and only the block values differ.

      natural  the graph as loaded
      unit     w = 1 (bfs: levels = unit-weight sssp)
      zero     w = 0 (cc: minplus relaxation degenerates to min-label
               propagation)
      shift    w = f32(w + S) with S = ``stride`` (default
               ``oracles.kreach_stride``): packed minplus fixpoints become
               lexicographic (hops, dist) minima for kreach
    """
    if variant == "natural":
        return g
    if variant == "unit":
        w = np.ones_like(g.weights)
    elif variant == "zero":
        w = np.zeros_like(g.weights)
    elif variant == "shift":
        if stride is None:
            stride = kreach_stride(
                g.n, float(g.weights.max()) if g.m else 1.0)
        w = (g.weights.astype(np.float32) + np.float32(stride)).astype(
            np.float32)
    else:
        raise ValueError(f"unknown weight variant {variant!r}; one of "
                         f"natural/unit/zero/shift")
    return CSRGraph(indptr=g.indptr, indices=g.indices, weights=w,
                    n=g.n, m=g.m)


def run_sssp(bg: BlockGraph, sources: np.ndarray,
             yield_config: Optional[YieldConfig] = None,
             schedule: str = "priority", use_pallas: bool = False,
             **run_kwargs) -> EngineResult:
    yc = yield_config or YieldConfig(
        delta=default_delta(float(np.nanmax(np.where(
            np.isfinite(bg.blocks), bg.blocks, np.nan)))))
    eng = FPPEngine(bg, mode="minplus", num_queries=len(sources),
                    yield_config=yc, schedule=schedule, use_pallas=use_pallas)
    return eng.run(np.asarray(sources), **run_kwargs)


def run_bfs(bg_unit: BlockGraph, sources: np.ndarray,
            yield_config: Optional[YieldConfig] = None,
            schedule: str = "priority", **run_kwargs) -> EngineResult:
    """bg_unit must be built from a unit-weight CSR (BFS = SSSP w=1).
    Returned values are float levels; +inf = unreachable."""
    yc = yield_config or YieldConfig(delta=1.0)  # Δ=1 == level-synchronous
    eng = FPPEngine(bg_unit, mode="minplus", num_queries=len(sources),
                    yield_config=yc, schedule=schedule)
    return eng.run(np.asarray(sources), **run_kwargs)


def run_ppr(bg: BlockGraph, sources: np.ndarray, alpha: float = 0.15,
            eps: float = 1e-4, yield_config: Optional[YieldConfig] = None,
            schedule: str = "priority", **run_kwargs) -> EngineResult:
    yc = yield_config or YieldConfig(mu_factor=100.0)  # paper's NCP setting
    eng = FPPEngine(bg, mode="push", num_queries=len(sources), alpha=alpha,
                    eps=eps, yield_config=yc, schedule=schedule)
    return eng.run(np.asarray(sources), **run_kwargs)


def run_cc(bg_zero: BlockGraph, sources: np.ndarray,
           schedule: str = "priority", **run_kwargs) -> EngineResult:
    """bg_zero must be built from the "zero" weight variant.  Returned values
    are raw reordered-rep labels (every lane identical); callers canonicalize
    via ``fpp.backends.canonicalize_cc`` after mapping to original ids."""
    eng = FPPEngine(bg_zero, mode="cc", num_queries=len(sources),
                    schedule=schedule)
    return eng.run(np.asarray(sources), **run_kwargs)


def run_kreach(bg_shift: BlockGraph, sources: np.ndarray, k: int,
               stride: float, schedule: str = "priority",
               **run_kwargs) -> EngineResult:
    """bg_shift must be built from the "shift" variant with the same
    ``stride``.  values = dist of the hop-minimal path where hops <= k
    (+inf beyond the budget); residual carries the hop plane."""
    eng = FPPEngine(bg_shift, mode="kreach", num_queries=len(sources),
                    schedule=schedule, hop_budget=k, hop_stride=stride)
    return eng.run(np.asarray(sources), **run_kwargs)


def run_rw(bg: BlockGraph, sources: np.ndarray, length: int = 32,
           seed: int = 0) -> WalkResult:
    return run_random_walks(bg, np.asarray(sources), length, seed=seed)


def prepare(g: CSRGraph, block_size: int, method: str = "bfs",
            unit_weights: bool = False, weights: Optional[str] = None):
    """One-stop: (block graph, perm).  ``weights`` picks the variant
    (:func:`reweight`); ``unit_weights=True`` is the legacy spelling of
    ``weights="unit"``."""
    variant = weights or ("unit" if unit_weights else "natural")
    return partition(reweight(g, variant), block_size, method=method)
