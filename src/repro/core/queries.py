"""Query-type facade: the FPP query types ForkGraph supports (paper §3).

BFS / SSSP ride the minplus engine, PPR rides the push engine, RW has its own
buffered walker loop, DFS is host-only (oracles.dfs_order; see DESIGN.md §2).
All functions take sources in the *reordered* vertex id space of ``bg`` (use
``perm[old_id]`` from partition()).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.engine import EngineResult, FPPEngine
from repro.core.graph import BlockGraph, CSRGraph
from repro.core.partition import partition
from repro.core.randomwalk import WalkResult, run_random_walks
from repro.core.yielding import YieldConfig, default_delta


def run_sssp(bg: BlockGraph, sources: np.ndarray,
             yield_config: Optional[YieldConfig] = None,
             schedule: str = "priority", use_pallas: bool = False,
             **run_kwargs) -> EngineResult:
    yc = yield_config or YieldConfig(
        delta=default_delta(float(np.nanmax(np.where(
            np.isfinite(bg.blocks), bg.blocks, np.nan)))))
    eng = FPPEngine(bg, mode="minplus", num_queries=len(sources),
                    yield_config=yc, schedule=schedule, use_pallas=use_pallas)
    return eng.run(np.asarray(sources), **run_kwargs)


def run_bfs(bg_unit: BlockGraph, sources: np.ndarray,
            yield_config: Optional[YieldConfig] = None,
            schedule: str = "priority", **run_kwargs) -> EngineResult:
    """bg_unit must be built from a unit-weight CSR (BFS = SSSP w=1).
    Returned values are float levels; +inf = unreachable."""
    yc = yield_config or YieldConfig(delta=1.0)  # Δ=1 == level-synchronous
    eng = FPPEngine(bg_unit, mode="minplus", num_queries=len(sources),
                    yield_config=yc, schedule=schedule)
    return eng.run(np.asarray(sources), **run_kwargs)


def run_ppr(bg: BlockGraph, sources: np.ndarray, alpha: float = 0.15,
            eps: float = 1e-4, yield_config: Optional[YieldConfig] = None,
            schedule: str = "priority", **run_kwargs) -> EngineResult:
    yc = yield_config or YieldConfig(mu_factor=100.0)  # paper's NCP setting
    eng = FPPEngine(bg, mode="push", num_queries=len(sources), alpha=alpha,
                    eps=eps, yield_config=yc, schedule=schedule)
    return eng.run(np.asarray(sources), **run_kwargs)


def run_rw(bg: BlockGraph, sources: np.ndarray, length: int = 32,
           seed: int = 0) -> WalkResult:
    return run_random_walks(bg, np.asarray(sources), length, seed=seed)


def prepare(g: CSRGraph, block_size: int, method: str = "bfs",
            unit_weights: bool = False):
    """One-stop: (block graph, perm) — unit_weights=True for BFS queries."""
    if unit_weights:
        g = CSRGraph(indptr=g.indptr, indices=g.indices,
                     weights=np.ones_like(g.weights), n=g.n, m=g.m)
    return partition(g, block_size, method=method)
