"""Graph containers: host-side CSR and the TPU-native block-sparse BlockGraph.

The BlockGraph is the paper's "LLC-sized partition" adapted to TPU: vertices are
reordered so each partition is a contiguous range of ``block_size`` vertices, and
the adjacency is stored as dense ``[B, B]`` blocks for every partition pair that
contains at least one edge.  Dense blocks are what a VPU/MXU can actually chew on;
block-sparsity recovers the graph's sparsity at partition granularity (the same
granularity the paper's buffers operate at).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass
class CSRGraph:
    """Host-side CSR. ``indptr[u]:indptr[u+1]`` are out-edges of ``u``."""

    indptr: np.ndarray   # int64 [n+1]
    indices: np.ndarray  # int32 [m]
    weights: np.ndarray  # float32 [m]
    n: int
    m: int

    @staticmethod
    def from_edges(n: int, src: np.ndarray, dst: np.ndarray,
                   weights: Optional[np.ndarray] = None,
                   symmetrize: bool = False,
                   dedup: bool = True) -> "CSRGraph":
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if weights is None:
            weights = np.ones(src.shape[0], dtype=np.float32)
        weights = np.asarray(weights, dtype=np.float32)
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            weights = np.concatenate([weights, weights])
        # drop self loops
        keep = src != dst
        src, dst, weights = src[keep], dst[keep], weights[keep]
        if dedup and src.size:
            key = src * np.int64(n) + dst
            order = np.argsort(key, kind="stable")
            key, src, dst, weights = key[order], src[order], dst[order], weights[order]
            first = np.concatenate([[True], key[1:] != key[:-1]])
            # keep the minimum weight among duplicates: since sorted stable, use
            # np.minimum.reduceat over groups
            starts = np.flatnonzero(first)
            weights = np.minimum.reduceat(weights, starts) if starts.size else weights
            src, dst = src[first], dst[first]
        order = np.argsort(src, kind="stable")
        src, dst, weights = src[order], dst[order], weights[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        indptr = np.cumsum(indptr)
        return CSRGraph(indptr=indptr, indices=dst.astype(np.int32),
                        weights=weights.astype(np.float32), n=n, m=int(dst.size))

    def out_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def permute(self, perm: np.ndarray) -> "CSRGraph":
        """Relabel vertices: new id of old vertex v is ``perm[v]``."""
        src = np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.indptr))
        return CSRGraph.from_edges(self.n, perm[src], perm[self.indices],
                                   self.weights, dedup=False)

    def edges(self):
        src = np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.indptr))
        return src, self.indices.astype(np.int64), self.weights


@dataclasses.dataclass
class BlockGraph:
    """Block-sparse dense-block adjacency over contiguous vertex partitions.

    Vertices are assumed already reordered (see partition.py) so that partition
    ``p`` owns vertices ``[p*B, (p+1)*B)`` of the padded id space.

    blocks      float32 [nblk, B, B]  blocks[k][u_loc, v_loc] = w(u, v), +inf absent
    blk_src     int32   [nblk]        source partition of block k
    blk_dst     int32   [nblk]        destination partition of block k
    nbr_blk     int32   [P, Dmax]     block ids of partition p's out-blocks (-1 pad),
                                      EXCLUDING the diagonal block
    nbr_part    int32   [P, Dmax]     destination partition per entry (-1 pad)
    diag_blk    int32   [P]           block id of (p, p); always present
    row_nnz     int32   [nblk, B]     out-degree of each local row within block k
    deg         int32   [P, B]        total out-degree of each vertex (padded: 0)
    vmask       bool    [P, B]        True for real (non padding) vertices
    """

    blocks: np.ndarray
    blk_src: np.ndarray
    blk_dst: np.ndarray
    nbr_blk: np.ndarray
    nbr_part: np.ndarray
    diag_blk: np.ndarray
    row_nnz: np.ndarray
    deg: np.ndarray
    vmask: np.ndarray
    block_size: int
    num_parts: int
    n: int                 # real vertex count (pre-padding)
    m: int

    @property
    def n_padded(self) -> int:
        return self.num_parts * self.block_size

    @staticmethod
    def from_csr(g: CSRGraph, block_size: int) -> "BlockGraph":
        B = int(block_size)
        P = max(1, -(-g.n // B))
        n_pad = P * B
        src, dst, w = g.edges()
        psrc = (src // B).astype(np.int64)
        pdst = (dst // B).astype(np.int64)
        pair = psrc * P + pdst
        # block ids for every (psrc, pdst) pair that appears, plus all diagonals
        diag_pairs = np.arange(P, dtype=np.int64) * P + np.arange(P, dtype=np.int64)
        uniq = np.unique(np.concatenate([pair, diag_pairs]))
        nblk = int(uniq.size)
        pair_to_blk = {int(pv): k for k, pv in enumerate(uniq)}
        blk_src = (uniq // P).astype(np.int32)
        blk_dst = (uniq % P).astype(np.int32)
        blocks = np.full((nblk, B, B), INF, dtype=np.float32)
        if src.size:
            bk = np.array([pair_to_blk[int(pv)] for pv in pair], dtype=np.int64)
            ul = (src % B).astype(np.int64)
            vl = (dst % B).astype(np.int64)
            # duplicate edges already removed in CSR; direct assignment keeps min
            flat = blocks.reshape(nblk, B * B)
            np.minimum.at(flat, (bk, ul * B + vl), w.astype(np.float32))
        diag_blk = np.array([pair_to_blk[int(p * P + p)] for p in range(P)],
                            dtype=np.int32)
        # neighbor lists excluding the diagonal
        nbrs: list[list[int]] = [[] for _ in range(P)]
        for k in range(nblk):
            if blk_src[k] != blk_dst[k]:
                nbrs[int(blk_src[k])].append(k)
        dmax = max(1, max((len(x) for x in nbrs), default=1))
        nbr_blk = np.full((P, dmax), -1, dtype=np.int32)
        nbr_part = np.full((P, dmax), -1, dtype=np.int32)
        for p in range(P):
            for j, k in enumerate(nbrs[p]):
                nbr_blk[p, j] = k
                nbr_part[p, j] = blk_dst[k]
        row_nnz = np.isfinite(blocks).sum(axis=2).astype(np.int32)
        deg = np.zeros((P, B), dtype=np.int32)
        full_deg = np.zeros(n_pad, dtype=np.int64)
        np.add.at(full_deg, src, 1)
        deg[:, :] = full_deg.reshape(P, B)
        vmask = (np.arange(n_pad).reshape(P, B) < g.n)
        return BlockGraph(blocks=blocks, blk_src=blk_src, blk_dst=blk_dst,
                          nbr_blk=nbr_blk, nbr_part=nbr_part, diag_blk=diag_blk,
                          row_nnz=row_nnz, deg=deg, vmask=vmask,
                          block_size=B, num_parts=P, n=g.n, m=g.m)

    def nbytes(self) -> int:
        return sum(a.nbytes for a in
                   (self.blocks, self.nbr_blk, self.nbr_part, self.diag_blk,
                    self.row_nnz, self.deg, self.vmask))

    def part_of(self, v: int) -> int:
        return int(v) // self.block_size

    def local_of(self, v: int) -> int:
        return int(v) % self.block_size


def vmem_block_size(vmem_bytes: int = 96 * 1024 * 1024,
                    num_queries: int = 256,
                    dtype_bytes: int = 4,
                    double_buffer: bool = True) -> int:
    """Pick B so (adjacency block + state tiles) fit VMEM — the paper's
    ``partition size = LLC size`` rule mapped to the TPU memory hierarchy.

    Working set per resident partition visit:
      adjacency block  B*B*dtype  (x2 if double buffered)
      dist tile        Q*B*dtype
      buffer tile      Q*B*dtype
    """
    mult = 2 if double_buffer else 1
    best = 128
    for b in (128, 256, 512, 1024, 2048, 4096):
        ws = mult * b * b * dtype_bytes + 2 * num_queries * b * dtype_bytes
        if ws <= vmem_bytes:
            best = b
    return best
