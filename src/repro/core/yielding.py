"""Yielding heuristics (paper §5.1) in dense form.

Heuristic 1 — edge budget: a query yields inside a partition visit once it has
processed more than ``mu_factor * |E_P| / |Q|`` edges this visit (μ is the
theoretical threshold from Appendix A; the paper sweeps 0.25μ..4μ and uses
100μ for NCP).

Heuristic 2 — value window: a query only relaxes operations whose value is
within ``delta_factor * delta`` of α, the best value it applied when the visit
started (Δ-stepping style; the paper adopts Δ from [44, 66]).

Both heuristics only *pause* work: yielded ops stay in the partition buffer and
are re-scheduled later, so results remain exact (paper §5.1).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class YieldConfig:
    # heuristic 1: per-query edge budget per visit = mu_factor * |E_P| / |Q|.
    # None disables the heuristic (budget = +inf).
    mu_factor: float | None = None
    # heuristic 2: absolute value window Δ. None disables.
    delta: float | None = None
    # hard cap on local relaxation rounds (correctness never depends on it —
    # pending ops survive in the buffer). Dense Bellman-Ford settles a B-vertex
    # partition in <= B rounds; PPR uses the cap as its only local limit.
    max_rounds: int = 0  # 0 => engine picks block_size for minplus / 64 for push

    def edge_budget(self, part_edges: np.ndarray, num_queries: int) -> np.ndarray:
        """Per-partition per-query edge budget (float32 [P])."""
        if self.mu_factor is None:
            return np.full(part_edges.shape, np.inf, dtype=np.float32)
        mu = part_edges.astype(np.float64) / max(1, num_queries)
        return np.maximum(1.0, self.mu_factor * mu).astype(np.float32)

    def window(self) -> float:
        return np.inf if self.delta is None else float(self.delta)


NO_YIELD = YieldConfig(mu_factor=None, delta=None)


def default_delta(weights_max: float) -> float:
    """Δ-stepping style default: the max edge weight (paper adopts the Δ used
    by [66] for Us; for synthetic uniform-[1, log n) weights w_max works)."""
    return float(max(1.0, weights_max))
